import random
import threading

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.mv import CatalogOverflowError, DiskStore, MemoryCatalog, table_nbytes


def test_catalog_accounting_and_overflow():
    cat = MemoryCatalog(100.0)
    cat.put("a", object(), 60.0)
    assert cat.used_bytes == 60.0
    assert cat.fits(40.0) and not cat.fits(41.0)
    with pytest.raises(CatalogOverflowError):
        cat.put("b", object(), 50.0)
    cat.put("b", object(), 40.0)
    assert cat.peak_bytes == 100.0
    cat.release("a")
    assert cat.used_bytes == 40.0
    assert "a" not in cat and "b" in cat
    # release is idempotent
    cat.release("a")


def test_catalog_rejects_duplicate():
    cat = MemoryCatalog(10.0)
    cat.put("a", 1, 1.0)
    with pytest.raises(KeyError):
        cat.put("a", 2, 1.0)


def test_diskstore_roundtrip_and_manifest(tmp_path):
    store = DiskStore(tmp_path)
    t = {"key": np.arange(10, dtype=np.int64), "c0": np.ones(10, np.float32)}
    store.write("mv1", t)
    assert store.exists("mv1")
    back = store.read("mv1")
    assert set(back) == set(t)
    for k in t:
        np.testing.assert_array_equal(back[k], t[k])
    assert store.manifest()["mv1"] == table_nbytes(t)
    store.delete("mv1")
    assert not store.exists("mv1")


def test_diskstore_throttle_and_counters(tmp_path):
    # 1 MB at 10 MB/s -> >= 0.1 s
    store = DiskStore(tmp_path, read_bw=10e6, write_bw=10e6, latency=0.0)
    t = {"x": np.zeros(1 << 18, np.float32)}  # 1 MiB
    wdt = store.write("big", t)
    assert wdt >= 0.09
    store.reset_counters()
    store.read("big")
    assert store.read_seconds >= 0.09


def test_diskstore_write_is_atomic(tmp_path):
    store = DiskStore(tmp_path)
    store.write("a", {"x": np.arange(4)})
    # a stray tmp file (simulated crash) must not appear in the manifest
    (tmp_path / "b.npz.tmp").write_bytes(b"partial")
    assert not store.exists("b")


def test_catalog_clear_resets_peak_and_reset_stats():
    cat = MemoryCatalog(100.0)
    cat.put("a", object(), 80.0)
    cat.release("a")
    assert cat.peak_bytes == 80.0
    # restart path: a reused catalog must not report the stale peak
    cat.clear()
    assert cat.peak_bytes == 0.0 and cat.used_bytes == 0.0
    cat.put("b", object(), 30.0)
    cat.put("c", object(), 20.0)
    cat.release("c")
    cat.reset_stats()  # keeps residents, resets peak to current usage
    assert "b" in cat and cat.peak_bytes == 30.0


@settings(max_examples=10)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_catalog_peak_semantics_under_concurrent_put_release(seed):
    """Property: under racing try_put/release from several threads — with a
    mid-run ``clear()`` (the engine restart path) and ``reset_stats()``
    thrown in — byte accounting never corrupts: usage stays within
    [0, budget], peak never exceeds the budget (atomic admission), and at
    quiescence usage equals the sum of resident entries, ``reset_stats``
    re-bases the peak to exactly that, and ``clear`` zeroes everything."""
    rng = random.Random(seed)
    budget = 1000.0
    cat = MemoryCatalog(budget)
    n_threads, n_ops = 4, 60
    sizes = [
        [rng.uniform(1.0, 400.0) for _ in range(n_ops)]
        for _ in range(n_threads)
    ]
    start = threading.Barrier(n_threads + 1)

    def worker(tid):
        start.wait()
        for i, size in enumerate(sizes[tid]):
            name = f"t{tid}e{i}"
            if cat.try_put(name, object(), size) and i % 3 != 0:
                cat.release(name)
            if i % 17 == 0:
                cat.reset_stats()

    threads = [
        threading.Thread(target=worker, args=(t,)) for t in range(n_threads)
    ]
    for th in threads:
        th.start()
    start.wait()
    cat.clear()  # restart mid-flight: must not break later accounting
    for th in threads:
        th.join()

    resident = cat.resident()
    assert cat.used_bytes == pytest.approx(sum(resident.values()))
    assert 0.0 <= cat.used_bytes <= budget + 1e-9
    assert cat.used_bytes <= cat.peak_bytes <= budget + 1e-9
    cat.reset_stats()
    assert cat.peak_bytes == pytest.approx(cat.used_bytes)
    cat.clear()
    assert cat.used_bytes == 0.0 and cat.peak_bytes == 0.0
    assert cat.resident() == {} and cat.fits(budget)


def test_diskstore_append_parts_roundtrip(tmp_path):
    store = DiskStore(tmp_path)
    t0 = {"key": np.arange(6, dtype=np.int64), "x": np.ones(6, np.float32)}
    d1 = {"key": np.arange(3, dtype=np.int64), "x": np.full(3, 2, np.float32)}
    d2 = {"key": np.arange(2, dtype=np.int64), "x": np.full(2, 3, np.float32)}
    store.write("mv", t0)
    store.append("mv", d1)
    store.append("mv", d2)
    assert store.parts("mv") == 3
    assert store.manifest()["mv"] == sum(map(table_nbytes, (t0, d1, d2)))
    full = store.read("mv")
    np.testing.assert_array_equal(
        full["x"], np.concatenate([t0["x"], d1["x"], d2["x"]])
    )
    # prefix = old content, suffix = the deltas
    np.testing.assert_array_equal(store.read_parts("mv", 0, 1)["x"], t0["x"])
    np.testing.assert_array_equal(
        store.read_parts("mv", 1)["x"], np.concatenate([d1["x"], d2["x"]])
    )
    # a full write replaces every part
    store.write("mv", t0)
    assert store.parts("mv") == 1
    assert store.manifest()["mv"] == table_nbytes(t0)
    np.testing.assert_array_equal(store.read("mv")["x"], t0["x"])


def test_diskstore_append_throttles_on_delta_bytes(tmp_path):
    # at 1 MB/s, charging total bytes (1 MiB + 4 KiB) would sleep >= 1.05s;
    # charging delta bytes sleeps ~4 ms (generous margin absorbs fsync noise)
    store = DiskStore(tmp_path, write_bw=1e6)
    big = {"x": np.zeros(1 << 18, np.float32)}   # 1 MiB
    small = {"x": np.zeros(1 << 10, np.float32)}  # 4 KiB
    store.write("mv", big)
    dt = store.append("mv", small)
    assert dt < 0.5, "append must be charged delta bytes, not total bytes"


def test_diskstore_rewrite_of_multipart_mv_is_crash_atomic(tmp_path):
    """A rewrite that crashes before the manifest commit must leave the old
    multi-part content fully intact (never new-part-0 + stale deltas)."""
    store = DiskStore(tmp_path)
    store.write("mv", {"x": np.arange(4)})
    store.append("mv", {"x": np.arange(4, 6)})
    # simulate a crashed write(): the new part lands on an id the manifest
    # does not reference, then the process dies before _record
    new_id = max(store._part_ids("mv")) + 1
    store._write_part("mv", new_id, {"x": np.full(3, 100)})
    np.testing.assert_array_equal(store.read("mv")["x"], np.arange(6))
    assert store.parts("mv") == 2
    # the next real write lands cleanly despite the orphan
    store.write("mv", {"x": np.full(3, 7)})
    np.testing.assert_array_equal(store.read("mv")["x"], np.full(3, 7))
    assert store.parts("mv") == 1


def _zset(rids, weight, **cols):
    t = {"rid": np.asarray(rids, np.int64),
         "weight": np.full(len(rids), weight, np.int64)}
    for k, v in cols.items():
        t[k] = np.asarray(v)
    return t


def test_diskstore_tombstone_append_retract_consolidate_roundtrip(tmp_path):
    """Z-set delta parts: updates splice at their old rid, deletes drop out,
    and reads consolidate — weight columns never reach the caller."""
    store = DiskStore(tmp_path)
    base = {"rid": np.arange(6, dtype=np.int64),
            "x": np.arange(6, dtype=np.float32)}
    store.write("mv", base)
    # round 1: update rid 1 (retract + reinsert), delete rid 4, insert rid 10
    d1 = {
        "rid": np.array([1, 4, 1, 10], np.int64),
        "weight": np.array([-1, -1, 1, 1], np.int64),
        "x": np.array([1.0, 4.0, 99.0, 10.0], np.float32),
    }
    store.append("mv", d1)
    assert store.parts("mv") == 2
    out = store.read("mv")
    assert "weight" not in out
    np.testing.assert_array_equal(out["rid"], [0, 1, 2, 3, 5, 10])
    np.testing.assert_array_equal(
        out["x"], np.array([0, 99, 2, 3, 5, 10], np.float32)
    )
    # round 2: delete the round-1 insert again
    store.append("mv", _zset([10], -1, x=np.array([10.0], np.float32)))
    out = store.read("mv")
    np.testing.assert_array_equal(out["rid"], [0, 1, 2, 3, 5])
    # prefix read = pre-round content; suffix read = the raw weighted delta
    np.testing.assert_array_equal(store.read_parts("mv", 0, 1)["x"], base["x"])
    suffix = store.read_parts("mv", 1, 2)
    assert "weight" in suffix and suffix["weight"].tolist() == [-1, -1, 1, 1]


def test_diskstore_consolidate_rewrites_single_live_part(tmp_path):
    store = DiskStore(tmp_path)
    base = {"rid": np.arange(8, dtype=np.int64),
            "x": np.ones(8, np.float32)}
    store.write("mv", base)
    store.append("mv", _zset([0, 1, 2], -1, x=np.ones(3, np.float32)))
    before = store.read("mv")
    bytes_with_tombstones = store.manifest()["mv"]
    dt = store.consolidate("mv")
    assert dt > 0.0
    assert store.parts("mv") == 1
    # manifest shrinks to live bytes; content is unchanged
    assert store.manifest()["mv"] == table_nbytes(before)
    assert store.manifest()["mv"] < bytes_with_tombstones
    after = store.read("mv")
    for k in before:
        np.testing.assert_array_equal(after[k], before[k])
    # idempotent no-op once single-part
    assert store.consolidate("mv") == 0.0


def test_diskstore_read_throttle_charges_tombstone_bytes(tmp_path):
    """Throttle pricing is keyed on the logical bytes read — retraction
    parts included — not on the (smaller) consolidated result: 2 MiB of
    parts at 10 MB/s must take >= ~0.2s even though nearly every row is
    retracted."""
    store = DiskStore(tmp_path, read_bw=10e6)
    n = 1 << 18
    base = {"rid": np.arange(n, dtype=np.int64),
            "x": np.zeros(n, np.float32)}   # ~3 MiB logical
    store.write("mv", base)
    kill = {"rid": base["rid"][:-16], "x": base["x"][:-16],
            "weight": np.full(n - 16, -1, np.int64)}
    store.append("mv", kill)
    store.reset_counters()
    out = store.read("mv")
    assert len(out["rid"]) == 16  # nearly everything retracted
    raw = table_nbytes(base) + table_nbytes(kill)
    assert store.read_seconds >= 0.9 * raw / 10e6


def test_diskstore_tombstone_crash_atomicity_and_stale_tmp_sweep(tmp_path):
    """A consolidation that crashes before the manifest commit leaves the
    tombstone parts authoritative; stale tmp files are ignored by readers
    and swept by delete."""
    store = DiskStore(tmp_path)
    store.write("mv", {"rid": np.arange(4, dtype=np.int64),
                       "x": np.arange(4, dtype=np.float32)})
    store.append("mv", _zset([0], -1, x=np.array([0.0], np.float32)))
    expect = store.read("mv")
    # simulated crash: the consolidated part lands on an unreferenced id and
    # a stale .tmp survives, but the process dies before _record
    new_id = max(store._part_ids("mv")) + 1
    store._write_part("mv", new_id, expect)
    (tmp_path / "mv.part99.npz.tmp").write_bytes(b"partial")
    fresh = DiskStore(tmp_path)  # reader after restart
    got = fresh.read("mv")
    for k in expect:
        np.testing.assert_array_equal(got[k], expect[k])
    assert fresh.parts("mv") == 2  # manifest still references base + delta
    # a later real consolidation overwrites the orphan and commits cleanly
    fresh.consolidate("mv")
    assert fresh.parts("mv") == 1
    fresh.delete("mv")
    assert list(tmp_path.glob("mv.*")) == []


def test_diskstore_tombstone_debt_accounting(tmp_path):
    """Appends accumulate a tombstone-debt estimate (tombstone rows plus
    their victims); full rewrites — consolidation included — reset it."""
    store = DiskStore(tmp_path)
    base = {"rid": np.arange(16, dtype=np.int64),
            "x": np.arange(16, dtype=np.float32)}
    store.write("mv", base)
    assert store.tombstone_bytes("mv") == 0
    assert store.live_bytes("mv") == table_nbytes(base)
    # insert-only appends carry no debt
    store.append("mv", {"rid": np.arange(16, 20, dtype=np.int64),
                        "x": np.zeros(4, np.float32)})
    assert store.tombstone_bytes("mv") == 0
    kill = _zset([0, 1, 2, 3], -1, x=np.zeros(4, np.float32))
    store.append("mv", kill)
    debt = store.tombstone_bytes("mv")
    assert debt > table_nbytes(kill)  # tombstones + their victims
    assert store.live_bytes("mv") == store.manifest()["mv"] - debt
    assert store.tombstone_ratio("mv") > 0.0
    store.append("mv", _zset([4, 5], -1, x=np.zeros(2, np.float32)))
    assert store.tombstone_bytes("mv") > debt  # debt accumulates
    store.consolidate("mv")
    assert store.tombstone_bytes("mv") == 0
    assert store.tombstone_ratio("mv") == 0.0
    assert store.live_bytes("mv") == store.manifest()["mv"]


def test_diskstore_delete_removes_parts_and_tmp(tmp_path):
    store = DiskStore(tmp_path)
    t = {"x": np.arange(8)}
    store.write("mv", t)
    store.append("mv", t)
    (tmp_path / "mv.npz.tmp").write_bytes(b"partial")  # crashed rewrite
    store.delete("mv")
    assert not store.exists("mv")
    assert list(tmp_path.glob("mv*.npz*")) == []
