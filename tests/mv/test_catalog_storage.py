import numpy as np
import pytest

from repro.mv import CatalogOverflowError, DiskStore, MemoryCatalog, table_nbytes


def test_catalog_accounting_and_overflow():
    cat = MemoryCatalog(100.0)
    cat.put("a", object(), 60.0)
    assert cat.used_bytes == 60.0
    assert cat.fits(40.0) and not cat.fits(41.0)
    with pytest.raises(CatalogOverflowError):
        cat.put("b", object(), 50.0)
    cat.put("b", object(), 40.0)
    assert cat.peak_bytes == 100.0
    cat.release("a")
    assert cat.used_bytes == 40.0
    assert "a" not in cat and "b" in cat
    # release is idempotent
    cat.release("a")


def test_catalog_rejects_duplicate():
    cat = MemoryCatalog(10.0)
    cat.put("a", 1, 1.0)
    with pytest.raises(KeyError):
        cat.put("a", 2, 1.0)


def test_diskstore_roundtrip_and_manifest(tmp_path):
    store = DiskStore(tmp_path)
    t = {"key": np.arange(10, dtype=np.int64), "c0": np.ones(10, np.float32)}
    store.write("mv1", t)
    assert store.exists("mv1")
    back = store.read("mv1")
    assert set(back) == set(t)
    for k in t:
        np.testing.assert_array_equal(back[k], t[k])
    assert store.manifest()["mv1"] == table_nbytes(t)
    store.delete("mv1")
    assert not store.exists("mv1")


def test_diskstore_throttle_and_counters(tmp_path):
    # 1 MB at 10 MB/s -> >= 0.1 s
    store = DiskStore(tmp_path, read_bw=10e6, write_bw=10e6, latency=0.0)
    t = {"x": np.zeros(1 << 18, np.float32)}  # 1 MiB
    wdt = store.write("big", t)
    assert wdt >= 0.09
    store.reset_counters()
    store.read("big")
    assert store.read_seconds >= 0.09


def test_diskstore_write_is_atomic(tmp_path):
    store = DiskStore(tmp_path)
    store.write("a", {"x": np.arange(4)})
    # a stray tmp file (simulated crash) must not appear in the manifest
    (tmp_path / "b.npz.tmp").write_bytes(b"partial")
    assert not store.exists("b")
