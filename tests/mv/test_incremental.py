"""Incremental refresh subsystem: full-vs-incremental scenarios end to end.

* incremental refresh of a realized workload is bitwise identical to a full
  recompute after every multi-round scenario (the acceptance property),
  across seeds, worker counts, update kinds (insert / update / delete /
  mixed), runtime join partial fallbacks, and static subtrees;
* every round of a multi-round incremental plan stays within the catalog
  budget at every worker count, and the round's plan is valid and feasible
  for the view graph it was solved against (the high-k property sweep —
  static-subtree skips change the window residency profile);
* the update-aware cost model: incremental views shrink short-circuitable
  bytes, statuses propagate per the delta rules, and simulated incremental
  rounds refresh faster than full rounds while S/C stays > 1x;
* the simulator's fed-forward per-round sizes track the real executor's
  manifest-observed sizes (sim-vs-real parity).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import CostModel
from repro.core.speedup import APPENDED, DELTA, REPLACED, STATIC
from repro.mv import (
    DiskStore,
    UpdateSpec,
    calibrate_sizes,
    generate_workload,
    incremental_view,
    paper_workloads,
    realize_workload,
    run_scenario,
    simulate_scenario,
    verify_scenario_equivalence,
)

CM = CostModel(
    disk_read_bw=50e6,
    disk_write_bw=50e6,
    mem_read_bw=1e12,
    mem_write_bw=1e12,
    disk_latency=0.0,
)


def build(tmp_path, n_nodes=14, seed=3, bytes_per_root=1 << 15, key_mod=None):
    wl = realize_workload(
        generate_workload(n_nodes=n_nodes, seed=seed),
        bytes_per_root=bytes_per_root,
        key_mod=key_mod,
    )
    return calibrate_sizes(wl, DiskStore(tmp_path / "calib"))


def run_both(tmp_path, wl, spec_kw, budget_frac=0.4, k=1):
    budget = sum(n.size for n in wl.nodes) * budget_frac
    reports, stores = {}, {}
    for mode in ("incremental", "full"):
        spec = UpdateSpec(mode=mode, **spec_kw)
        store = DiskStore(tmp_path / mode)
        stores[mode] = store
        reports[mode] = run_scenario(
            wl, store, budget, spec, CM, n_compute_workers=k
        )
    verify_scenario_equivalence(wl, stores["incremental"], stores["full"])
    return reports, stores, budget


# ---------------------------------------------------------------------------
# (a) bitwise equivalence of incremental refresh vs full recompute
# ---------------------------------------------------------------------------

def test_incremental_bitwise_equals_full_recompute(tmp_path):
    wl = build(tmp_path)
    reports, _, budget = run_both(
        tmp_path, wl, dict(ingest_frac=0.3, n_rounds=3)
    )
    inc = reports["incremental"]
    assert len(inc.rounds) == 4
    # refresh rounds must actually exercise the delta paths
    appended = sum(
        sum(1 for s in r.statuses.values() if s == APPENDED)
        for r in inc.rounds[1:]
    )
    assert appended > 0
    assert all(
        r.run.peak_catalog_bytes <= budget + 1e-9 for r in inc.rounds
    )


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_incremental_bitwise_property(seed):
    import shutil
    import tempfile
    from pathlib import Path

    tmp_path = Path(tempfile.mkdtemp(prefix=f"inc{seed}_"))
    try:
        wl = build(tmp_path, n_nodes=10, seed=seed, bytes_per_root=1 << 13)
        run_both(tmp_path, wl, dict(ingest_frac=0.25, n_rounds=2))
    finally:
        shutil.rmtree(tmp_path, ignore_errors=True)


def test_join_new_keys_need_no_full_recompute(tmp_path):
    """A huge key space makes right-side deltas introduce new join keys; the
    Z-set partial fallback re-joins only *newly-matched old-left rows* — with
    a sparse key space there are none, so refresh stays a pure delta (no
    fallback work at all) and the result is still bitwise identical."""
    wl = build(tmp_path, seed=3, key_mod=1 << 30)
    assert any(len(n.parents) >= 2 and n.op == "JOIN" for n in wl.nodes)
    reports, _, _ = run_both(tmp_path, wl, dict(ingest_frac=0.3, n_rounds=2))
    inc = reports["incremental"]
    assert sum(r.join_fallbacks for r in inc.rounds) == 0
    assert not any(
        s == REPLACED
        for r in inc.rounds[1:]
        for name, s in r.statuses.items()
        if any(n.name == name and n.op == "JOIN" for n in wl.nodes)
    )


def test_join_partial_fallback_on_right_side_updates(tmp_path):
    """Right-side UPDATEs rewrite first-occurrence match payloads, so the
    engine must splice retract/insert corrections for the affected old-left
    rows (the partial fallback) — and stay bitwise identical to the full
    recompute."""
    wl = build(tmp_path, seed=3)
    assert any(len(n.parents) >= 2 and n.op == "JOIN" for n in wl.nodes)
    reports, _, _ = run_both(
        tmp_path, wl, dict(ingest_frac=0.1, update_frac=0.2, n_rounds=2)
    )
    fallbacks = sum(r.join_fallbacks for r in reports["incremental"].rounds)
    assert fallbacks > 0


def test_static_subtrees_are_skipped(tmp_path):
    """With a partial ingest set, subtrees fed only by static scans are
    skipped in refresh rounds and their stored MVs stay untouched."""
    wl = build(tmp_path, seed=7)
    roots = [i for i, n in enumerate(wl.nodes) if not n.parents]
    assert len(roots) >= 2
    spec_kw = dict(ingest_frac=0.3, n_rounds=2, ingest=(roots[0],))
    view = incremental_view(wl, UpdateSpec(mode="incremental", **spec_kw), 1)
    statuses = view.meta["update"]["statuses"]
    static = {wl.nodes[i].name for i, s in enumerate(statuses) if s == STATIC}
    assert static, "seed must produce a static subtree"
    reports, stores, _ = run_both(tmp_path, wl, spec_kw)
    for r in reports["incremental"].rounds[1:]:
        assert static <= set(r.run.skipped)
    # static MVs still single-part (never rewritten or appended)
    for name in static:
        assert stores["incremental"].parts(name) == 1


def test_union_over_ridless_static_agg_side_stays_bitwise(tmp_path):
    """A UNION whose one input is AGG-derived (no rid) cannot use the append
    rule even when that side is static — the engine must recompute it fully
    and stay bitwise identical to the full-mode run."""
    from repro.mv import MVNode, Workload

    spec_nodes = [
        MVNode("mv0", (), "SCAN", 1e6, 0.0),
        MVNode("mv1", (), "SCAN", 1e6, 0.0),
        MVNode("mv2", (1,), "AGG", 1e5, 0.0),
        MVNode("mv3", (0, 2), "UNION", 1e6, 0.0),
        MVNode("mv4", (3,), "FILTER", 5e5, 0.0),
    ]
    wl = realize_workload(Workload("union_agg", spec_nodes),
                          bytes_per_root=1 << 14)
    wl = calibrate_sizes(wl, DiskStore(tmp_path / "calib"))
    reports, _, _ = run_both(
        tmp_path, wl, dict(ingest_frac=0.3, n_rounds=2, ingest=(0,))
    )
    # the union must not have taken the append path
    for r in reports["incremental"].rounds[1:]:
        assert r.statuses["mv3"] != APPENDED


def test_multiround_budget_respected_at_every_k(tmp_path):
    """Acceptance: a multi-round incremental plan stays within the catalog
    budget at every round for every worker count."""
    for k in (1, 2, 3):
        wl = build(tmp_path / f"k{k}", seed=5)
        reports, _, budget = run_both(
            tmp_path / f"k{k}", wl, dict(ingest_frac=0.25, n_rounds=3),
            budget_frac=0.3, k=k,
        )
        for mode, rep in reports.items():
            for r in rep.rounds:
                assert r.run.peak_catalog_bytes <= budget + 1e-9, (mode, k)


# acceptance: mixed insert/update/delete rounds stay bitwise across
# >= 3 seeds and k in {1, 2, 4} (run_both verifies incremental vs full
# recompute on the real executor inside)
@pytest.mark.parametrize("seed", [3, 11, 2026])
@pytest.mark.parametrize("k", [1, 2, 4])
def test_mixed_update_kinds_bitwise_across_seeds_and_k(tmp_path, seed, k):
    wl = build(tmp_path, n_nodes=10, seed=seed, bytes_per_root=1 << 13)
    reports, _, budget = run_both(
        tmp_path, wl,
        dict(ingest_frac=0.15, update_frac=0.15, delete_frac=0.1, n_rounds=2),
        k=k,
    )
    inc = reports["incremental"]
    # retraction-carrying deltas must actually flow (not collapse to full)
    assert any(
        s == DELTA for r in inc.rounds[1:] for s in r.statuses.values()
    )
    assert all(r.run.peak_catalog_bytes <= budget + 1e-9 for r in inc.rounds)


@pytest.mark.parametrize("kind", ["update", "delete"])
def test_pure_update_and_delete_scenarios_bitwise(tmp_path, kind):
    """UPDATE-only and DELETE-only rounds (no ingest at all) refresh
    incrementally and stay bitwise identical to full recompute."""
    wl = build(tmp_path, n_nodes=12, seed=6, bytes_per_root=1 << 13)
    kw = dict(ingest_frac=0.0, n_rounds=2)
    kw["update_frac" if kind == "update" else "delete_frac"] = 0.25
    reports, stores, _ = run_both(tmp_path, wl, kw)
    inc = reports["incremental"]
    assert any(
        s in (DELTA, REPLACED) for r in inc.rounds[1:]
        for s in r.statuses.values()
    )
    if kind == "delete":
        # deletes must actually shrink some scan's stored content
        scan = next(n.name for n in wl.nodes if not n.parents)
        n0 = len(stores["incremental"].read_parts(scan, 0, 1)["key"])
        n_now = len(stores["incremental"].read(scan)["key"])
        assert n_now < n0


HYP_KINDS = (
    dict(ingest_frac=0.25),
    dict(ingest_frac=0.0, update_frac=0.2),
    dict(ingest_frac=0.0, delete_frac=0.2),
    dict(ingest_frac=0.1, update_frac=0.1, delete_frac=0.1),
)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 6), st.integers(0, 3))
def test_highk_round_budget_and_plan_validity_sweep(seed, k, kind):
    """ROADMAP sweep: incremental rounds at high worker counts k — static
    subtree skips change the window residency profile, so assert, for every
    round, that the solved plan is valid (a topological permutation solved
    for k) and feasible for the view graph it was planned against, and that
    the executed round's true catalog peak stays within budget."""
    import shutil
    import tempfile
    from pathlib import Path

    from repro.mv import run_scenario as _run

    tmp_path = Path(tempfile.mkdtemp(prefix=f"sweep{seed}_"))
    try:
        wl = build(tmp_path, n_nodes=10, seed=seed, bytes_per_root=1 << 13)
        roots = [i for i, n in enumerate(wl.nodes) if not n.parents]
        # partial ingest set: leaves static subtrees when the DAG has them
        ingest = tuple(roots[: max(1, len(roots) - 1)])
        spec = UpdateSpec(mode="incremental", n_rounds=2, ingest=ingest,
                          **HYP_KINDS[kind])
        budget = sum(n.size for n in wl.nodes) * 0.3
        rep = _run(wl, DiskStore(tmp_path / "s"), budget, spec, CM,
                   n_compute_workers=k)
        for r in rep.rounds:
            assert sorted(r.plan.order) == list(range(wl.n))
            assert r.plan.n_workers == k  # solved for the executing k
            view = (
                wl if r.round_idx == 0
                else incremental_view(
                    wl, spec, 1, sizes=r.sizes,
                    fallback_rate=r.fallback_stats["rate_used"],
                )
            )
            g = view.to_graph(CM)
            assert g.is_topological(r.plan.order)
            assert g.is_feasible(r.plan.flagged, r.plan.order, budget, k)
            assert r.run.peak_catalog_bytes <= budget + 1e-9
            if r.round_idx:
                static = {
                    wl.nodes[i].name
                    for i, s in enumerate(view.meta["update"]["statuses"])
                    if s == STATIC
                }
                assert static <= set(r.run.skipped)
    finally:
        shutil.rmtree(tmp_path, ignore_errors=True)


def test_sim_vs_real_per_round_size_parity(tmp_path):
    """The simulator feeds each round's planner the previous round's modeled
    full sizes, as the real engine feeds manifest-observed sizes: per round,
    the two size vectors must agree in aggregate (the analytic linear-growth
    model vs real delta bytes, tombstones included)."""
    wl = build(tmp_path, n_nodes=12, seed=9, bytes_per_root=1 << 14)
    spec = UpdateSpec(mode="incremental", ingest_frac=0.2, update_frac=0.1,
                      delete_frac=0.05, n_rounds=3)
    budget = sum(n.size for n in wl.nodes) * 0.4
    real = run_scenario(wl, DiskStore(tmp_path / "real"), budget, spec, CM)
    sim = simulate_scenario(wl, spec, CM, budget)
    assert len(real.rounds) == len(sim.rounds)
    for rr, sr in zip(real.rounds, sim.rounds):
        assert len(rr.sizes) == len(sr.sizes) == wl.n
        ratio = sum(sr.sizes) / sum(rr.sizes)
        assert 0.5 < ratio < 2.0, (rr.round_idx, ratio)
    # the feedback is genuinely per-round: sim sizes must evolve
    assert sim.rounds[1].sizes != sim.rounds[-1].sizes


def test_scenario_catalog_hits_and_appends(tmp_path):
    """Refresh rounds short-circuit deltas through the catalog and append
    delta parts on storage rather than rewriting appended MVs."""
    wl = build(tmp_path, seed=11)
    reports, stores, _ = run_both(tmp_path, wl, dict(ingest_frac=0.3, n_rounds=2))
    inc = reports["incremental"]
    assert all(r.run.catalog_hits > 0 for r in inc.rounds)
    appended_names = {
        name
        for r in inc.rounds[1:]
        for name, s in r.statuses.items()
        if s == APPENDED
    }
    assert any(stores["incremental"].parts(n) > 1 for n in appended_names)


# ---------------------------------------------------------------------------
# (b) update-aware cost model / planner
# ---------------------------------------------------------------------------

def test_incremental_view_shrinks_short_circuitable_bytes():
    wl = generate_workload(20, seed=4)
    spec = UpdateSpec(mode="incremental", ingest_frac=0.05, n_rounds=1)
    view = incremental_view(wl, spec, 1)
    assert sum(n.size for n in view.nodes) < sum(n.size for n in wl.nodes)
    statuses = view.meta["update"]["statuses"]
    # delta-propagating nodes carry delta-scale update bytes
    for i, s in enumerate(statuses):
        if s == APPENDED:
            assert view.nodes[i].size <= 0.5 * wl.nodes[i].size
    for i, node in enumerate(wl.nodes):
        if statuses[i] == REPLACED and node.op != "AGG":
            assert any(statuses[p] == REPLACED for p in node.parents)
        if any(statuses[p] == REPLACED for p in node.parents):
            assert statuses[i] == REPLACED
    # full-mode views keep full sizes on every non-scan node
    full_view = incremental_view(wl, UpdateSpec(mode="full", ingest_frac=0.05), 1)
    for i, node in enumerate(wl.nodes):
        if node.parents:
            assert full_view.nodes[i].size >= wl.nodes[i].size


def test_update_mode_changes_flagging():
    """Incremental scoring changes which nodes are worth flagging under the
    same budget — the planner must re-solve per update mode."""
    from repro.core import solve

    wl = generate_workload(24, seed=8)
    budget = sum(n.size for n in wl.nodes) * 0.01
    g_full = wl.to_graph(CM)
    g_inc = wl.to_graph(CM, update=UpdateSpec(mode="incremental", ingest_frac=0.05))
    pf = solve(g_full, budget=budget)
    pi = solve(g_inc, budget=budget)
    assert pi.flagged != pf.flagged
    # deltas are small: the same byte budget flags more nodes incrementally
    assert len(pi.flagged) > len(pf.flagged)


def test_simulated_incremental_rounds_beat_full_rounds():
    """Paper axis on the simulator: incremental rounds refresh faster than
    full rounds, and S/C short-circuiting still yields > 1x within the same
    memory budget in both modes."""
    from repro.core.speedup import EFFECTIVE_NFS_COST_MODEL

    wl = paper_workloads(10.0)[0]
    budget = 10.0 * 1e9 * 0.016
    res = {}
    for mode in ("full", "incremental"):
        spec = UpdateSpec(mode=mode, ingest_frac=0.05, n_rounds=2)
        for method in ("serial", "sc"):
            rep = simulate_scenario(
                wl, spec, EFFECTIVE_NFS_COST_MODEL, budget, method=method
            )
            res[(mode, method)] = rep.refresh_seconds
    assert res[("incremental", "sc")] < res[("full", "sc")]
    assert res[("incremental", "serial")] < res[("full", "serial")]
    assert res[("full", "serial")] / res[("full", "sc")] > 1.0
    assert res[("incremental", "serial")] / res[("incremental", "sc")] > 1.0


# ---------------------------------------------------------------------------
# (c) tombstone consolidation scheduler + fallback-rate calibration
# ---------------------------------------------------------------------------

def test_consolidation_policy_bounds_tombstone_debt(tmp_path):
    """ROADMAP debt: a long DELETE-heavy scenario with the consolidation
    scheduler armed keeps every MV's tombstone debt bounded by the
    configured ratio (the policy fires inside the round's timed window),
    stays bitwise identical to the full recompute, and without the policy
    the debt grows past the threshold."""
    ratio = 0.5
    wl = build(tmp_path, n_nodes=8, seed=4, bytes_per_root=1 << 13)
    budget = sum(n.size for n in wl.nodes) * 0.4
    kw = dict(ingest_frac=0.05, delete_frac=0.2, n_rounds=5)
    spec = UpdateSpec(mode="incremental", **kw)
    store = DiskStore(tmp_path / "pol")
    rep = run_scenario(wl, store, budget, spec, CM, consolidate_ratio=ratio)
    assert sum(r.run.consolidations for r in rep.rounds) > 0
    for n in wl.nodes:
        assert store.tombstone_ratio(n.name) <= ratio + 1e-9, n.name
    # un-scheduled baseline: debt exceeds the threshold somewhere
    bare = DiskStore(tmp_path / "bare")
    run_scenario(wl, bare, budget, spec, CM)
    assert any(bare.tombstone_ratio(n.name) > ratio for n in wl.nodes)
    # correctness is untouched by consolidation timing
    full = DiskStore(tmp_path / "full")
    run_scenario(wl, full, budget, UpdateSpec(mode="full", **kw), CM)
    verify_scenario_equivalence(wl, store, full)


def test_join_fallback_rate_observed_and_fed_forward(tmp_path):
    """Right-side updates trigger partial fallbacks; the engine records the
    observed affected/matched key profile per round and later rounds'
    planners use the EWMA-smoothed observed rate in the correction-cost
    term (first observation == plain ratio, so round 2 sees mat/aff)."""
    wl = build(tmp_path, seed=3)
    reports, _, _ = run_both(
        tmp_path, wl, dict(ingest_frac=0.1, update_frac=0.2, n_rounds=3)
    )
    rounds = reports["incremental"].rounds
    assert all(r.fallback_stats is not None for r in rounds)
    assert rounds[1].fallback_stats["rate_used"] == 1.0  # no observations yet
    aff = sum(r.fallback_stats["affected"] for r in rounds[:2])
    mat = sum(r.fallback_stats["matched"] for r in rounds[:2])
    assert aff > 0, "scenario must actually exercise the partial fallback"
    assert rounds[2].fallback_stats["rate_used"] == pytest.approx(mat / aff)
    assert 0.0 <= rounds[2].fallback_stats["rate_used"] <= 1.0


def test_propagate_update_scales_join_corrections_by_fallback_rate():
    """The calibrated correction-cost term: a lower observed fallback rate
    shrinks a JOIN's modeled update bytes under right-side churn without
    flipping its DELTA status."""
    from repro.core.speedup import propagate_update

    ops = ["SCAN", "SCAN", "JOIN"]
    parents = [(), (), (0, 1)]
    sizes = [1e6, 1e6, 2e6]
    kw = dict(
        computes=[0.1] * 3, base_reads=[1e6, 1e6, 0.0], ingest={0, 1},
        frac=0.0, update_frac=0.1,
    )
    hi = propagate_update(ops, parents, sizes, **kw)
    lo = propagate_update(ops, parents, sizes, join_fallback_rate=0.25, **kw)
    zero = propagate_update(ops, parents, sizes, join_fallback_rate=0.0, **kw)
    assert hi.statuses[2] == lo.statuses[2] == zero.statuses[2] == DELTA
    assert lo.update_bytes[2] < hi.update_bytes[2]
    assert zero.update_bytes[2] <= lo.update_bytes[2]


def test_round_zero_is_identical_across_modes(tmp_path):
    """The build round is mode-independent: same plans, same stored bytes."""
    wl = build(tmp_path, seed=2, n_nodes=10)
    reports, stores, _ = run_both(tmp_path, wl, dict(ingest_frac=0.2, n_rounds=1))
    a = reports["incremental"].rounds[0]
    b = reports["full"].rounds[0]
    assert a.plan.order == b.plan.order
    assert a.plan.flagged == b.plan.flagged
    assert set(a.run.executed) == set(b.run.executed)


def test_consolidation_fires_on_round_zero(tmp_path):
    """Regression: the consolidation scheduler used to skip round 0
    entirely. A retraction-heavy initial load that already breaches the
    debt ratio must consolidate before round 1's timed window inherits the
    debt — the real precondition is parts > 1 (old content to fold into),
    not the round index."""
    from repro.mv import tableops as T
    from repro.mv.incremental import IncrementalEngine

    wl = build(tmp_path, n_nodes=3, seed=0, bytes_per_root=1 << 12)
    store = DiskStore(tmp_path / "r0")
    name = wl.nodes[0].name
    base = T.make_base_table(200, 3, seed=1, rid_base=T.make_rid_base(0, 0))
    store.write(name, base)
    dead = {k: np.asarray(v)[:150].copy() for k, v in base.items()}
    dead[T.WEIGHT_COL] = np.full(150, -1, np.int64)
    store.append(name, dead)
    assert store.parts(name) > 1
    assert store.tombstone_ratio(name) > 0.5

    engine = IncrementalEngine(
        wl, store, budget_bytes=1e9,
        spec=UpdateSpec(mode="incremental"), consolidate_ratio=0.5,
    )
    engine.configure_round(0)
    assert engine._finalize_run() >= 1
    assert store.parts(name) == 1
    assert store.tombstone_ratio(name) <= 0.5


def test_fallback_rate_ewma_recovers_after_churn_spike():
    """Regression: the fed-forward JOIN fallback rate was a cumulative
    ratio, so one churn spike pinned the correction-cost term near 1.0 for
    the rest of a long scenario. The EWMA estimator forgets the spike
    within a few quiet rounds."""
    from repro.mv.incremental import FallbackRateEwma

    ewma = FallbackRateEwma()
    assert ewma.rate == 1.0  # conservative prior before any observation

    ewma.observe(1000, 1000)  # churn spike: every affected key matched
    assert ewma.rate == 1.0
    for _ in range(3):
        ewma.observe(10, 0)   # quiet rounds
    assert ewma.rate < 0.15   # alpha=0.5: 1.0 -> 0.5 -> 0.25 -> 0.125

    # the old cumulative estimator would still be pinned near the spike
    cumulative = (1000 + 0) / (1000 + 30)
    assert cumulative > 0.95

    ewma.observe(0, 0)        # rounds with no affected keys don't update
    assert ewma.rate == pytest.approx(0.125)
