"""Merge soundness and bitwise parity of MQO shared-subexpression
compilation (mv/mqo.py, DESIGN.md §11).

Covers the merge-soundness matrix: opaque closures never merge,
param-differing FILTERs never merge, fingerprints are deterministic and
partition-aware across P=4 lifts, and the merged DAG stays bitwise
identical to the unshared workload across seeds × update kinds × worker
counts while executing each shared subtree exactly once per round. The
adaptive full-vs-incremental chooser rides the same scenario machinery, so
its parity and forcing behavior are asserted here too.
"""
from __future__ import annotations

import dataclasses as dc
from collections import Counter

import pytest

from repro.core import CostModel
from repro.core.speedup import choose_refresh_modes
from repro.mv import (
    DiskStore,
    UpdateSpec,
    calibrate_sizes,
    generate_workload,
    realize_workload,
    run_scenario,
    verify_scenario_equivalence,
)
from repro.mv import ir as mvir
from repro.mv.mqo import (
    merge_workload,
    node_fingerprints,
    shared_prefix_workload,
    verify_merged_equivalence,
)
from repro.mv.partition import partition_workload

CM = CostModel(
    disk_read_bw=50e6,
    disk_write_bw=50e6,
    mem_read_bw=1e12,
    mem_write_bw=1e12,
    disk_latency=0.0,
)


def build(tmp_path, n_views=3, seed=3, bytes_per_root=1 << 13):
    wl = realize_workload(
        shared_prefix_workload(n_views=n_views),
        bytes_per_root=bytes_per_root, seed=seed,
    )
    return calibrate_sizes(wl, DiskStore(tmp_path / "calib"))


def run_pair(tmp_path, wl, merged, spec_kw, k=1, budget_frac=0.5):
    budget = sum(n.size for n in merged.workload.nodes) * budget_frac
    spec = UpdateSpec(mode="incremental", **spec_kw)
    store_u = DiskStore(tmp_path / "unshared")
    store_m = DiskStore(tmp_path / "merged")
    rep_u = run_scenario(wl, store_u, budget, spec, CM, n_compute_workers=k)
    rep_m = run_scenario(merged.workload, store_m, budget, spec, CM,
                         n_compute_workers=k)
    return rep_u, rep_m, store_u, store_m


# ---------------------------------------------------------------------------
# merge soundness: what must and must not merge
# ---------------------------------------------------------------------------

def test_shared_prefix_merges_expected_classes(tmp_path):
    wl = build(tmp_path)
    merged = merge_workload(wl)
    assert wl.n == 23 and merged.workload.n == 19
    assert merged.n_merged_away == 4
    assert merged.shared == ("v0_filter", "v0_join")
    assert merged.classes["v0_filter"] == (2, 9, 16)
    assert merged.classes["v0_join"] == (3, 10, 17)
    # consumers are rewired onto the representatives; every original view
    # name resolves through name_map
    assert merged.name_map["v2_filter"] == "v0_filter"
    assert merged.name_map["v1_join"] == "v0_join"
    # kept nodes preserve topological order (parents before children)
    for i, n in enumerate(merged.workload.nodes):
        assert all(p < i for p in n.parents)


def test_opaque_closures_never_merge(tmp_path):
    """A hand-written closure the lifter cannot classify fingerprints
    opaque-unique: it never joins an equivalence class, and its downstream
    consumers stop merging too (their input fingerprints diverge)."""
    wl = build(tmp_path)

    def opaque(inputs):
        t = inputs[0]
        return t

    nodes = list(wl.nodes)
    for i, n in enumerate(nodes):
        if n.name in ("v0_filter", "v1_filter"):
            nodes[i] = dc.replace(n, fn=opaque)
    wl2 = dc.replace(wl, nodes=nodes)

    ir = mvir.infer_schemas(mvir.lift_workload(wl2))
    assert not ir.nodes[2].lifted and not ir.nodes[9].lifted
    fps = node_fingerprints(ir)
    assert fps[2] != fps[9]  # identical bodies, still never equal
    merged = merge_workload(wl2, ir)
    assert merged.n_merged_away == 0
    assert not merged.shared


def test_param_differing_filters_never_merge():
    """Two FILTERs over the same scan whose node indices are not congruent
    mod 7 carry different lifted thresholds — structurally similar, never
    equal."""
    from repro.mv.workloads import MVNode, Workload

    wl = Workload(name="param_diff", nodes=[
        MVNode("scan", (), "SCAN", 1e6, 0.0, base_read=1e6),
        MVNode("f1", (0,), "FILTER", 7e5, 1e-4),
        MVNode("f2", (0,), "FILTER", 7e5, 1e-4),
    ])
    ir = mvir.infer_schemas(mvir.lift_workload(wl))
    assert dict(ir.nodes[1].params)["threshold"] != \
        dict(ir.nodes[2].params)["threshold"]
    fps = node_fingerprints(ir)
    assert fps[1] != fps[2]
    assert merge_workload(wl, ir).n_merged_away == 0


def test_fingerprints_stable_and_partition_aware(tmp_path):
    """Fingerprinting is deterministic across independent lifts, and a P=4
    partition expansion merges only within a partition — the partition tag
    is part of the node's identity, so replicas never collapse across
    shards."""
    wl = build(tmp_path)
    fp1 = node_fingerprints(mvir.infer_schemas(mvir.lift_workload(wl)))
    fp2 = node_fingerprints(mvir.infer_schemas(mvir.lift_workload(wl)))
    assert fp1 == fp2

    pwl, _ = partition_workload(wl, 4)
    pir = mvir.infer_schemas(mvir.lift_workload(pwl))
    fps = node_fingerprints(pir)
    names = [n.name for n in pwl.nodes]
    v0f = [i for i, n in enumerate(names) if n.startswith("v0_filter")]
    assert len(v0f) == 4
    assert len({fps[i] for i in v0f}) == 4  # distinct across partitions
    pm = merge_workload(pwl, pir)
    for rep, members in pm.classes.items():
        if len(members) < 2:
            continue
        parts = {names[m].rsplit("@", 1)[-1] for m in members}
        assert len(parts) == 1, f"{rep} merged across partitions: {members}"
    # each partition still finds its own filter+join class
    assert sum(len(v) > 1 for v in pm.classes.values()) == 8


def test_merged_workload_relifts_fully(tmp_path):
    """Compiled delta programs on merged nodes carry their parameter
    provenance (``param_src``), so the merged workload itself re-lifts with
    every node inspectable — merges of merges stay verifiable."""
    merged = merge_workload(build(tmp_path))
    re_ir = mvir.lift_workload(merged.workload)
    assert all(n.lifted for n in re_ir.nodes)


# ---------------------------------------------------------------------------
# bitwise parity + once-per-round execution
# ---------------------------------------------------------------------------

SPEC_KW = {
    "insert": dict(ingest_frac=0.25, n_rounds=2),
    "mixed": dict(ingest_frac=0.2, update_frac=0.15, delete_frac=0.1,
                  n_rounds=2),
}


@pytest.mark.parametrize("seed,kind,k", [
    (3, "insert", 1),
    (3, "mixed", 2),
    (5, "insert", 2),
    (5, "mixed", 1),
    (7, "mixed", 1),
])
def test_merged_bitwise_parity_matrix(tmp_path, seed, kind, k):
    """Every original view's stored bytes under the shared DAG are
    bitwise-identical to the unshared run's, across seeds × update kinds ×
    worker counts."""
    wl = build(tmp_path, seed=seed)
    merged = merge_workload(wl)
    _, _, store_u, store_m = run_pair(
        tmp_path, wl, merged, SPEC_KW[kind], k=k
    )
    verify_merged_equivalence(merged, store_m, store_u)


def test_shared_subtree_executes_once_per_round(tmp_path):
    """The merged run refreshes each shared representative exactly once per
    round while the unshared run pays once per class member."""
    wl = build(tmp_path)
    merged = merge_workload(wl)
    rep_u, rep_m, _, _ = run_pair(tmp_path, wl, merged, SPEC_KW["mixed"])
    for r in rep_m.rounds:
        counts = Counter(r.run.executed)
        assert max(counts.values()) == 1
        for rep in merged.shared:
            assert counts[rep] == 1, (r.round_idx, rep)
    for r in rep_u.rounds[1:]:
        counts = Counter(r.run.executed)
        for rep, members in merged.classes.items():
            if len(members) < 2:
                continue
            names = [wl.nodes[m].name for m in members]
            assert sum(counts[n] for n in names) == len(members)


# ---------------------------------------------------------------------------
# adaptive full-vs-incremental (Enzyme-style per-view-per-round choice)
# ---------------------------------------------------------------------------

def test_adaptive_mode_bitwise_and_forces_full(tmp_path):
    """mode="adaptive" flips individual views to full recompute when the
    modeled incremental path is costlier (churn-heavy rounds), records the
    choice in ``RoundReport.forced_full``, and stays bitwise identical to
    both static modes — the chooser is performance-only."""
    wl = calibrate_sizes(
        realize_workload(generate_workload(n_nodes=14, seed=3),
                         bytes_per_root=1 << 15),
        DiskStore(tmp_path / "calib"),
    )
    budget = sum(n.size for n in wl.nodes) * 0.4
    kw = dict(ingest_frac=0.25, update_frac=0.25, delete_frac=0.1,
              n_rounds=3)
    stores, reports = {}, {}
    for mode in ("adaptive", "incremental", "full"):
        store = DiskStore(tmp_path / mode)
        stores[mode] = store
        reports[mode] = run_scenario(
            wl, store, budget, UpdateSpec(mode=mode, **kw), CM
        )
    rounds = reports["adaptive"].rounds
    assert rounds[0].forced_full == ()  # round 0 builds everything anyway
    assert any(r.forced_full for r in rounds[1:]), (
        "churn-heavy scenario should force at least one view to full"
    )
    for other in ("incremental", "full"):
        verify_scenario_equivalence(wl, stores["adaptive"], stores[other])
    # static modes never force
    assert all(r.forced_full == () for r in reports["incremental"].rounds)


def test_choose_refresh_modes_tracks_fallback_rate():
    """The node-local chooser prices the JOIN partial-fallback correction
    with the observed rate: a hot rate forces the JOIN to full recompute
    under update churn, a cold rate keeps it incremental."""
    ops = ["SCAN", "SCAN", "JOIN"]
    parents = [(), (), (0, 1)]
    sizes = [1e6, 1e6, 2e6]
    kw = dict(
        computes=[0.01] * 3, base_reads=[1e6, 1e6, 0.0], ingest={0, 1},
        frac=0.05, update_frac=0.3, cost_model=CM,
    )
    hot = choose_refresh_modes(ops, parents, sizes,
                               join_fallback_rate=1.0, **kw)
    cold = choose_refresh_modes(ops, parents, sizes,
                                join_fallback_rate=0.0, **kw)
    assert 2 in hot
    assert 2 not in cold
