"""Multi-host partition refresh (DESIGN.md §13): fault-injection chaos
suite, per-host budget accounting, and the bitwise acceptance matrix.

* multi-host == single-host, bitwise: with partitions spread over H hosts
  (each under its own Memory Catalog budget), every stored MV equals the
  single-host partitioned run — fault-free and under every injected fault
  (mid-round host kill, sustained straggler delay, preemption during
  write-behind), across seeds × hosts ∈ {1, 2, 4} × update kinds;
* every recovery re-dispatches work (visible in the round report and as
  ``redispatch`` trace events) and replays onto coordinator-assigned part
  ids, so duplicate/late results are idempotent;
* catalog accounting survives re-dispatch: a dead host's entries are
  dropped, duplicate admissions are released immediately, and every
  surviving host ends the round at ``used_bytes == 0`` (the leak
  regression);
* a host flagged as a straggler in one round is healthy state again the
  next round and receives work.
"""
import tempfile

import pytest

from repro.core import CostModel
from repro.core.altopt import solve_multihost
from repro.mv import (
    DiskStore,
    FaultAction,
    FaultPlan,
    HostPool,
    StragglerConfig,
    UpdateSpec,
    generate_workload,
    partition_workload,
    place_partitions,
    realize_workload,
    run_multihost_scenario,
    run_partitioned_scenario,
    verify_scenario_equivalence,
)
from repro.mv.partition import expand_update_spec
from repro.obs import trace as obs_trace

CM = CostModel(
    disk_read_bw=50e6,
    disk_write_bw=50e6,
    mem_read_bw=1e12,
    mem_write_bw=1e12,
    disk_latency=0.0,
)

P = 4
BUDGET = 1 << 22

SPECS = {
    "insert": UpdateSpec(mode="incremental", n_rounds=2, ingest_frac=0.3),
    "update": UpdateSpec(mode="incremental", n_rounds=2, ingest_frac=0.2,
                         update_frac=0.15),
    "delete": UpdateSpec(mode="incremental", n_rounds=2, ingest_frac=0.2,
                         delete_frac=0.1),
    "adaptive": UpdateSpec(mode="adaptive", n_rounds=2, ingest_frac=0.3,
                           update_frac=0.1),
}


def build_workload(seed=7):
    wl = generate_workload(n_nodes=10, seed=seed)
    return realize_workload(wl, bytes_per_root=1 << 16, seed=seed,
                            key_skew=1.0)


_ref_cache: dict = {}


def reference_store(seed, spec_key):
    """Fault-free single-host partitioned run (the bitwise oracle), cached
    per (seed, update kind) for the whole module."""
    key = (seed, spec_key)
    if key not in _ref_cache:
        store = DiskStore(tempfile.mkdtemp(prefix="mh-ref-"))
        run_partitioned_scenario(
            build_workload(seed), P, store, BUDGET, SPECS[spec_key], CM
        )
        _ref_cache[key] = store
    return _ref_cache[key]


def run_mh(seed, spec_key, n_hosts, **kw):
    store = DiskStore(tempfile.mkdtemp(prefix="mh-"))
    rep = run_multihost_scenario(
        build_workload(seed), P, store, [BUDGET / n_hosts] * n_hosts,
        SPECS[spec_key], CM, round_timeout=60.0, **kw,
    )
    return rep, store


def assert_matches_reference(store, seed, spec_key):
    pwl, _ = partition_workload(build_workload(seed), P)
    verify_scenario_equivalence(pwl, reference_store(seed, spec_key), store)


def assert_no_catalog_leak(rep):
    for rnd in rep.rounds:
        for hs in rnd.host_stats:
            if hs.alive:
                assert hs.used_bytes == 0.0, (
                    f"round {rnd.round_idx} host {hs.host}: "
                    f"{hs.used_bytes} bytes leaked in the catalog"
                )


# ---------------------------------------------------------------------------
# fault-free: single- and multi-host bitwise equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_hosts", [1, 2, 4])
def test_fault_free_bitwise_thread(n_hosts):
    rep, store = run_mh(7, "insert", n_hosts, backend="thread")
    assert_matches_reference(store, 7, "insert")
    assert_no_catalog_leak(rep)
    assert not rep.redispatches and not rep.hosts_lost


def test_fault_free_bitwise_process():
    rep, store = run_mh(7, "update", 2, backend="process")
    assert_matches_reference(store, 7, "update")
    assert_no_catalog_leak(rep)
    assert not rep.hosts_lost


def test_bytes_placement_matches_hash_bitwise():
    """Placement moves partitions between hosts, never changes their bytes."""
    rep, store = run_mh(7, "insert", 2, backend="thread", placement="bytes")
    assert_matches_reference(store, 7, "insert")
    assert rep.placement != place_partitions(P, 2) or True  # any placement ok


# ---------------------------------------------------------------------------
# chaos: kill / delay / preempt
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["thread", "process"])
def test_kill_mid_round_recovers_bitwise(backend):
    fp = FaultPlan((FaultAction("kill", host=1, round_idx=1, after_tasks=1),))
    rep, store = run_mh(7, "update", 2, backend=backend, fault_plan=fp)
    assert_matches_reference(store, 7, "update")
    assert rep.hosts_lost == [1]
    assert any(r.reason == "dead" for r in rep.redispatches)
    assert all(r.from_host == 1 for r in rep.redispatches)
    assert_no_catalog_leak(rep)
    # the dead host executes nothing from the loss on
    lost_round = next(r for r in rep.rounds if r.hosts_lost)
    for rnd in rep.rounds[lost_round.round_idx + 1:]:
        assert not rnd.host_stats[1].alive


@pytest.mark.parametrize("backend", ["thread", "process"])
def test_preempt_during_write_behind_recovers_bitwise(backend):
    fp = FaultPlan(
        (FaultAction("preempt", host=0, round_idx=1, after_tasks=1),)
    )
    rep, store = run_mh(7, "insert", 2, backend=backend, fault_plan=fp)
    assert_matches_reference(store, 7, "insert")
    assert rep.hosts_lost == [0]
    assert rep.redispatches
    assert_no_catalog_leak(rep)


def test_straggler_delay_redispatches_and_stays_bitwise():
    """A host delayed past the straggler threshold is flagged mid-round and
    its pending partitions run speculatively on the survivors — without the
    host dying, and without changing a byte."""
    fp = FaultPlan(
        (FaultAction("delay", host=2, round_idx=1, after_tasks=0,
                     seconds=0.4),)
    )
    rep, store = run_mh(
        7, "insert", 4, backend="thread", fault_plan=fp,
        straggler=StragglerConfig(threshold=2.0, patience=2, interval=0.05),
    )
    assert_matches_reference(store, 7, "insert")
    assert not rep.hosts_lost  # flagged, not lost
    assert any(r.reason == "straggler" for r in rep.redispatches)
    flagged = [e for rnd in rep.rounds for e in rnd.straggler_events]
    assert any(e.host == 2 for e in flagged)
    # duplicate/late admissions from the suspect host must have been
    # released: every host (suspect included) ends each round empty
    assert_no_catalog_leak(rep)


def test_flagged_then_recovered_host_gets_work_again():
    """Straggler suspicion is per round: a host flagged in round 1 (delay
    cleared at the round boundary) executes work again in round 2."""
    fp = FaultPlan(
        (FaultAction("delay", host=2, round_idx=1, after_tasks=0,
                     seconds=0.4),)
    )
    rep, store = run_mh(
        7, "insert", 4, backend="thread", fault_plan=fp,
        straggler=StragglerConfig(threshold=2.0, patience=2, interval=0.05),
    )
    assert_matches_reference(store, 7, "insert")
    flagged_rounds = [
        rnd.round_idx for rnd in rep.rounds
        if any(r.reason == "straggler" for r in rnd.redispatches)
    ]
    assert flagged_rounds, "delay never tripped the straggler detector"
    later = [r for r in rep.rounds if r.round_idx > max(flagged_rounds)]
    assert later and all(
        rnd.host_stats[2].executed > 0 for rnd in later
    ), "recovered host never received work again"


def test_redispatch_visible_in_trace_spans():
    fp = FaultPlan((FaultAction("kill", host=1, round_idx=1, after_tasks=0),))
    was = obs_trace.enabled()
    obs_trace.enable(True)
    obs_trace.clear()
    try:
        rep, store = run_mh(7, "insert", 2, backend="thread", fault_plan=fp)
        spans = obs_trace.drain()
    finally:
        obs_trace.enable(was)
    assert_matches_reference(store, 7, "insert")
    rd = [s for s in spans if s.cat == "redispatch"]
    assert len(rd) == len(rep.redispatches)
    # re-dispatch events land on the receiving host's track
    assert {s.track for s in rd} <= {f"host{h}" for h in range(2)}
    assert all(s.worker == "coord" for s in rd)


def test_all_hosts_lost_raises():
    fp = FaultPlan((
        FaultAction("kill", host=0, round_idx=1, after_tasks=0),
        FaultAction("kill", host=1, round_idx=1, after_tasks=0),
    ))
    with pytest.raises(RuntimeError, match="no surviving host"):
        run_mh(7, "insert", 2, backend="thread", fault_plan=fp)


# ---------------------------------------------------------------------------
# catalog accounting on re-dispatch (the leak regression)
# ---------------------------------------------------------------------------

def test_dead_host_catalog_entries_are_dropped():
    """Regression: partitions admitted by a host that dies mid-round must be
    released before replay — the killed host's catalog is cleared and no
    survivor carries phantom ``used_bytes`` past round end."""
    wl = build_workload(7)
    pwl, pmap = partition_workload(wl, P)
    espec = expand_update_spec(SPECS["insert"], pmap)
    store = DiskStore(tempfile.mkdtemp(prefix="mh-leak-"))
    budgets = [BUDGET / 2] * 2
    fp = FaultPlan((FaultAction("kill", host=1, round_idx=0, after_tasks=2),))
    pool = HostPool(pwl, store, budgets, espec, backend="thread",
                    fault_plan=fp, round_timeout=60.0)
    try:
        g = pwl.to_graph(CM)
        plan = solve_multihost(g, budgets, P)
        rep = pool.run_round(0, plan, sizes=[n.size for n in pwl.nodes])
        assert rep.hosts_lost == [1]
        assert rep.redispatches
        # the killed host's engine object survives on the thread backend —
        # its catalog must have been force-cleared by the coordinator
        assert pool.host_catalog_used(1) == 0.0
        assert pool.host_catalog_used(0) == 0.0
        for hs in rep.host_stats:
            if hs.alive:
                assert hs.used_bytes == 0.0
    finally:
        pool.shutdown()


# ---------------------------------------------------------------------------
# placement unit behavior
# ---------------------------------------------------------------------------

def test_place_partitions_hash_and_bytes():
    assert place_partitions(6, 2) == (0, 1, 0, 1, 0, 1)
    assert place_partitions(4, 1) == (0, 0, 0, 0)
    # greedy bytes balancing: the two heavy partitions split across hosts
    pl = place_partitions(4, 2, bytes_per_partition=[100, 90, 5, 5],
                          strategy="bytes")
    assert pl[0] != pl[1]
    loads = [0.0, 0.0]
    for p, h in enumerate(pl):
        loads[h] += [100, 90, 5, 5][p]
    assert abs(loads[0] - loads[1]) <= 10
    with pytest.raises(ValueError, match="bytes_per_partition"):
        place_partitions(4, 2, strategy="bytes")
    with pytest.raises(ValueError, match="unknown placement"):
        place_partitions(4, 2, bytes_per_partition=[1, 1, 1, 1],
                         strategy="nope")


def test_fault_plan_for_host():
    a = FaultAction("kill", host=1)
    b = FaultAction("delay", host=0, seconds=0.5)
    fp = FaultPlan((a, b))
    assert fp.for_host(1) == (a,)
    assert fp.for_host(0) == (b,)
    assert fp.for_host(3) == ()


# ---------------------------------------------------------------------------
# acceptance matrix (slow): seeds × hosts × update kinds × faults
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("seed", [7, 11, 23])
@pytest.mark.parametrize("n_hosts", [1, 2, 4])
@pytest.mark.parametrize("spec_key", sorted(SPECS))
def test_acceptance_matrix_bitwise(seed, n_hosts, spec_key):
    """The full ISSUE matrix: every (seed, hosts, update kind) cell — with a
    mid-round kill injected whenever there is a host to spare — completes
    bitwise identical to the fault-free single-host run."""
    fp = None
    if n_hosts > 1:
        fp = FaultPlan((
            FaultAction("kill", host=n_hosts - 1, round_idx=1,
                        after_tasks=1),
        ))
    rep, store = run_mh(seed, spec_key, n_hosts, backend="thread",
                        fault_plan=fp)
    assert_matches_reference(store, seed, spec_key)
    assert_no_catalog_leak(rep)
    if n_hosts > 1:
        assert rep.hosts_lost == [n_hosts - 1]
        assert rep.redispatches
