"""Shared test configuration.

With ``hypothesis`` installed, registers two fixed profiles and loads the
one named by ``$HYPOTHESIS_PROFILE`` (default ``dev``):

* ``ci``  — deadline disabled (shared-runner timing jitter must not fail
  property tests) and ``derandomize=True`` (explicit seed derandomization:
  every run draws the same deterministic example sequence, so a CI failure
  reproduces locally byte for byte);
* ``dev`` — deadline disabled only.

When the real package is not installed, provides a minimal stand-in:
``given``/``settings``/``strategies`` run a fixed, deterministic sample of
drawn cases (seeded from the test identity — effectively always
derandomized), so the property tests still collect and execute (with
reduced case coverage) on dependency-free environments.
"""
from __future__ import annotations

import os
import sys

try:  # pragma: no cover - exercised only when hypothesis is present
    import hypothesis  # noqa: F401
    from hypothesis import settings as _hsettings

    _hsettings.register_profile("ci", deadline=None, derandomize=True)
    _hsettings.register_profile("dev", deadline=None)
    _hsettings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ModuleNotFoundError:
    import random
    import types

    # Fixed sample size per property test: enough for smoke coverage without
    # the shrinking/coverage machinery of the real library.
    _MAX_EXAMPLES_CAP = 20

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def draw_with(self, rng: random.Random):
            return self._sample(rng)

    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)

    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def sampled_from(elements) -> _Strategy:
        pool = list(elements)
        return _Strategy(lambda rng: rng.choice(pool))

    def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
        return _Strategy(
            lambda rng: [
                elements.draw_with(rng)
                for _ in range(rng.randint(min_size, max_size))
            ]
        )

    class _Data:
        """Stand-in for the object ``st.data()`` tests draw from."""

        def __init__(self, rng: random.Random):
            self._rng = rng

        def draw(self, strategy: _Strategy, label=None):
            return strategy.draw_with(self._rng)

    _DATA_SENTINEL = object()

    def data():
        return _DATA_SENTINEL

    def given(*strategies, **kw_strategies):
        def decorate(fn):
            def runner():
                cfg = getattr(runner, "_shim_settings", {})
                n = min(int(cfg.get("max_examples", _MAX_EXAMPLES_CAP)),
                        _MAX_EXAMPLES_CAP)
                for example in range(n):
                    # seed from the test identity: deterministic across runs
                    rng = random.Random(
                        f"{fn.__module__}.{fn.__qualname__}:{example}"
                    )

                    def materialize(s):
                        return _Data(rng) if s is _DATA_SENTINEL else s.draw_with(rng)

                    args = [materialize(s) for s in strategies]
                    kwargs = {k: materialize(s) for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)

            # keep the test's identity but hide the parameter signature so
            # pytest does not treat the drawn arguments as fixtures
            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__module__ = fn.__module__
            runner.__doc__ = fn.__doc__
            return runner

        return decorate

    def settings(**kwargs):
        def decorate(fn):
            fn._shim_settings = kwargs
            return fn

        return decorate

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _st = types.ModuleType("hypothesis.strategies")
    for _name, _obj in (
        ("integers", integers),
        ("booleans", booleans),
        ("floats", floats),
        ("sampled_from", sampled_from),
        ("lists", lists),
        ("data", data),
    ):
        setattr(_st, _name, _obj)
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
