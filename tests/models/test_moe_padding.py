"""Expert-count padding (§Perf cell D): padded, router-masked experts must be
exact no-ops, and padded counts enable EP sharding for qwen's 60 experts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import forward, init_params


def test_padded_experts_never_routed_and_noop():
    cfg0 = get_config("qwen2-moe-a2.7b").reduced(
        moe_experts=6, moe_capacity_factor=16.0, dtype="float32"
    )
    cfg1 = dataclasses.replace(cfg0, pad_experts_to=8)
    params1 = init_params(cfg1, jax.random.PRNGKey(0))
    # padded expert weights are zero-initialized
    w_in = np.asarray(params1["blocks"]["sub0"]["ffn"]["w_in"])
    assert (w_in[:, 6:] == 0).all()

    def strip(p):
        q = jax.tree.map(lambda x: x, p)
        for sub in q["blocks"].values():
            if "ffn" in sub and "router" in sub["ffn"]:
                f = sub["ffn"]
                f["router"] = f["router"][..., :6]
                f["w_in"] = f["w_in"][:, :6]
                f["w_out"] = f["w_out"][:, :6]
        return q

    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg0.vocab_size)
    a = forward(cfg0, strip(params1), tokens)[0]
    b = forward(cfg1, params1, tokens)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


def test_padding_enables_ep_sharding():
    import functools

    from repro.sharding.strategy import param_specs
    from tests.sharding.test_strategy import MESHES

    cfg = dataclasses.replace(get_config("qwen2-moe-a2.7b"), pad_experts_to=64)
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    pshape = jax.eval_shape(functools.partial(init_params, cfg), key_sds)
    spec = param_specs(cfg, pshape, MESHES["single"])
    w_in = spec["blocks"]["sub0"]["ffn"]["w_in"]
    assert tuple(w_in)[1] == "model"  # EP now available (64 % 16 == 0)
    from repro.sharding.strategy import audit_divisibility

    assert audit_divisibility(cfg, pshape, MESHES["single"]) == []
