"""Per-architecture smoke tests: reduced same-family config, one forward +
one train (grad) step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import init_params, lm_loss, forward

BATCH, SEQ = 2, 32


def make_batch(cfg, key):
    kt, kp = jax.random.split(key)
    if cfg.frontend == "vlm":
        n_patch = cfg.vlm_patches
        tokens = jax.random.randint(kt, (BATCH, SEQ - n_patch), 0, cfg.vocab_size)
        labels = jnp.concatenate(
            [
                jnp.full((BATCH, n_patch), -1, jnp.int32),
                jax.random.randint(kp, (BATCH, SEQ - n_patch), 0, cfg.vocab_size),
            ],
            axis=1,
        )
        patch = jax.random.normal(kp, (BATCH, n_patch, cfg.d_model), jnp.bfloat16)
        return {"tokens": tokens, "labels": labels, "patch_embeds": patch}
    tokens = jax.random.randint(kt, (BATCH, SEQ), 0, cfg.vocab_size)
    labels = jax.random.randint(kp, (BATCH, SEQ), 0, cfg.vocab_size)
    return {"tokens": tokens, "labels": labels}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    logits, aux, _ = jax.jit(
        lambda p, b: forward(cfg, p, b["tokens"], b.get("patch_embeds"))
    )(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch}: NaN logits"

    def loss_fn(p):
        return lm_loss(cfg, p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"
    flat = jax.tree.leaves(grads)
    assert flat, "no grads"
    for g in flat:
        assert np.isfinite(np.asarray(g, np.float32)).all(), f"{arch}: NaN grad"
    # at least one non-zero grad
    assert any(float(jnp.abs(g.astype(jnp.float32)).sum()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ["llama3-405b", "arctic-480b"])
def test_padded_heads_are_noops(arch):
    """Zero-initialized padded head slices must not change the forward."""
    cfg = get_config("llava-next-34b").reduced(
        n_heads=6, pad_heads_to=8, n_kv_heads=2, frontend="tokens", head_dim=8
    )
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, _, _ = forward(cfg, params, batch["tokens"])
    # unpadded sibling with identical unpadded weights
    cfg2 = cfg.reduced(n_heads=6, pad_heads_to=0, n_kv_heads=2, head_dim=8,
                       frontend="tokens")

    def strip(p):
        from repro.models.layers import head_pad_mask

        q = p["blocks"]["sub0"]["mixer"]["wq"]
        o = p["blocks"]["sub0"]["mixer"]["wo"]
        hd = cfg.head_dim_
        keep = np.repeat(np.asarray(head_pad_mask(cfg)), hd)  # kv-group layout
        p2 = jax.tree.map(lambda x: x, p)
        p2["blocks"]["sub0"]["mixer"]["wq"] = q[..., keep]
        p2["blocks"]["sub0"]["mixer"]["wo"] = o[..., keep, :]
        return p2

    logits2, _, _ = forward(cfg2, strip(params), batch["tokens"])
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(logits2, np.float32),
        atol=1e-3, rtol=1e-3,
    )


def test_vocab_padding_masked():
    cfg = get_config("mamba2-2.7b").reduced(vocab_size=250, pad_vocab_to=64)
    assert cfg.vocab_padded == 256
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.zeros((1, 8), jnp.int32)
    logits, _, _ = forward(cfg, params, tokens)
    pad_logits = np.asarray(logits[..., 250:], np.float32)
    assert (pad_logits <= -1e8).all(), "padded vocab logits must be masked"


def test_param_count_analytic_matches_actual():
    for arch in ("gemma-7b", "qwen2-moe-a2.7b", "mamba2-2.7b", "jamba-v0.1-52b"):
        cfg = get_config(arch).reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        from repro.models import count_params_analytic

        analytic = count_params_analytic(cfg)
        assert actual == analytic, f"{arch}: actual {actual} != analytic {analytic}"
