"""Serving path: prefill + incremental decode must match the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, forward, init_params, make_cache, prefill

# one dense, one GQA, one SSM, one hybrid-MoE — covers every cache kind
ARCHS = ["stablelm-12b", "mamba2-2.7b", "jamba-v0.1-52b", "musicgen-large"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_plus_decode_matches_forward(arch):
    # float32 so the check validates *logic* exactly (bf16 accumulation noise
    # between the chunked prefill-state path and the sequential decode
    # recurrence otherwise drifts past tight tolerances). Capacity factor set
    # drop-free: token dropping is batch-dependent by construction, so the
    # full-forward oracle only matches when no MoE tokens are dropped.
    cfg = get_config(arch).reduced(dtype="float32", moe_capacity_factor=16.0)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    b, prompt_len, gen_len = 2, 16, 4
    total = prompt_len + gen_len
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, total), 0, cfg.vocab_size)

    # oracle: full forward over the whole sequence
    full_logits, _, _ = forward(cfg, params, tokens)

    # serving: prefill prompt, then decode token-by-token (teacher-forced)
    cache = make_cache(cfg, b, total)
    last, cache = prefill(cfg, params, tokens[:, :prompt_len], cache)
    np.testing.assert_allclose(
        np.asarray(last, np.float32),
        np.asarray(full_logits[:, prompt_len - 1], np.float32),
        atol=2e-2, rtol=2e-2,
    )
    for t in range(prompt_len, total):
        step_logits, cache = decode_step(
            cfg, params, tokens[:, t], cache, jnp.int32(t)
        )
        np.testing.assert_allclose(
            np.asarray(step_logits, np.float32),
            np.asarray(full_logits[:, t], np.float32),
            atol=2e-2, rtol=2e-2,
            err_msg=f"{arch}: decode step {t} diverged from forward",
        )


def test_decode_is_jittable_and_shape_stable():
    cfg = get_config("stablelm-3b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, max_len = 2, 32
    cache = make_cache(cfg, b, max_len)
    step = jax.jit(lambda tok, c, pos: decode_step(cfg, params, tok, c, pos))
    tok = jnp.zeros((b,), jnp.int32)
    logits, cache = step(tok, cache, jnp.int32(0))
    assert logits.shape == (b, cfg.vocab_padded)
    logits2, cache = step(tok + 1, cache, jnp.int32(1))
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
