"""Sharding rules: divisibility audit for all 10 archs on both meshes, spec
structure checks, and an end-to-end sharded train/decode on 8 host devices."""
import functools
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import init_params


class FakeMesh:
    """Just axis names + shape: enough for spec construction/audit without
    touching real devices."""

    def __init__(self, shape, names):
        import numpy as np

        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


MESHES = {
    "single": FakeMesh((16, 16), ("data", "model")),
    "multi": FakeMesh((2, 16, 16), ("pod", "data", "model")),
}


@pytest.mark.parametrize("mesh_kind", ["single", "multi"])
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_divisibility_all_archs(arch, mesh_kind):
    from repro.sharding.strategy import audit_divisibility

    cfg = get_config(arch)
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    pshape = jax.eval_shape(functools.partial(init_params, cfg), key_sds)
    mesh = MESHES[mesh_kind]
    problems = audit_divisibility(cfg, pshape, mesh)
    assert problems == [], f"{arch} on {mesh_kind}: {problems}"
    # ZeRO specs must audit clean too
    from repro.sharding.strategy import opt_state_specs

    problems = audit_divisibility(
        cfg, pshape, mesh, specs=opt_state_specs(cfg, pshape, mesh)
    )
    assert problems == [], f"{arch} opt-state on {mesh_kind}: {problems}"


def test_kv_replicated_when_small():
    from jax.sharding import PartitionSpec as P

    from repro.sharding.strategy import param_specs

    cfg = get_config("llama3-405b")  # kv=8 < 16
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    pshape = jax.eval_shape(functools.partial(init_params, cfg), key_sds)
    specs = param_specs(cfg, pshape, MESHES["single"])
    wk = specs["blocks"]["sub0"]["mixer"]["wk"]
    assert tuple(wk)[-1] is None  # kv head dim not sharded
    wq = specs["blocks"]["sub0"]["mixer"]["wq"]
    assert tuple(wq)[-1] == "model"


def test_moe_ep_vs_ffn_sharding():
    from repro.sharding.strategy import param_specs

    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    # arctic: 128 experts -> EP over model
    cfg = get_config("arctic-480b")
    pshape = jax.eval_shape(functools.partial(init_params, cfg), key_sds)
    spec = param_specs(cfg, pshape, MESHES["single"])
    w_in = spec["blocks"]["sub0"]["ffn"]["w_in"]
    assert tuple(w_in)[1] == "model"
    # qwen: 60 experts -> per-expert ffn TP
    cfg = get_config("qwen2-moe-a2.7b")
    pshape = jax.eval_shape(functools.partial(init_params, cfg), key_sds)
    spec = param_specs(cfg, pshape, MESHES["single"])
    w_in = spec["blocks"]["sub0"]["ffn"]["w_in"]
    assert tuple(w_in)[1] is None and tuple(w_in)[-1] == "model"


@pytest.mark.slow
def test_sharded_train_and_decode_execute_on_8_devices():
    """Actually EXECUTES (not just compiles) a sharded train step + decode
    step on 8 host devices in a subprocess."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import functools, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import init_params, make_cache
        from repro.serve.step import make_decode_step
        from repro.sharding.strategy import param_specs, cache_specs
        from repro.train.step import init_train_state, make_train_step, train_state_specs

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("qwen2-moe-a2.7b").reduced(
            d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
            moe_experts=8, moe_top_k=2, head_dim=16)
        params = init_params(cfg, jax.random.PRNGKey(0))
        pshape = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        sspec = train_state_specs(cfg, pshape, mesh)
        # deep-copy params into the train state: step() donates the state and
        # we reuse `params` for the decode path below
        state = init_train_state(cfg, jax.tree.map(jnp.copy, params))
        state = jax.device_put(state, ns(sspec))
        batch = {
            "tokens": jnp.zeros((8, 32), jnp.int32),
            "labels": jnp.zeros((8, 32), jnp.int32),
        }
        bspec = {"tokens": P(("data",), None), "labels": P(("data",), None)}
        batch = jax.device_put(batch, ns(bspec))
        step = jax.jit(make_train_step(cfg, dp=2, global_rows=8),
                       in_shardings=(ns(sspec), ns(bspec)),
                       out_shardings=(ns(sspec), None), donate_argnums=(0,))
        state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), loss

        # decode on the same mesh
        cache = make_cache(cfg, 8, 16)
        cspec = cache_specs(cfg, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache), mesh)
        pspec = param_specs(cfg, pshape, mesh)
        dec = jax.jit(make_decode_step(cfg),
                      in_shardings=(ns(pspec), NamedSharding(mesh, P(("data",))),
                                    ns(cspec), NamedSharding(mesh, P())),
                      donate_argnums=(2,))
        cache = jax.device_put(cache, ns(cspec))
        params_s = jax.device_put(params, ns(pspec))
        logits, cache = dec(params_s, jnp.zeros((8,), jnp.int32), cache,
                            jnp.int32(0))
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        print("OK", loss)
        """
    )
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=".", timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
