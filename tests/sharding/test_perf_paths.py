"""Beyond-paper perf paths: shard_map EP MoE equivalence, sequence-parallel
activations, and seq-sharded KV cache specs — all on an 8-device subprocess
mesh (device count locks at first jax init, so these cannot run in-process)."""
import subprocess
import sys
import textwrap

import pytest


@pytest.mark.slow
def test_shard_map_moe_matches_gather_and_perf_overrides_compile():
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import dataclasses, jax, numpy as np
        import repro.launch.dryrun as dr
        from repro.configs import get_config
        from repro.configs.base import ShapeSpec
        from repro.sharding.context import mesh_context
        from repro.models import init_params, forward

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg_g = get_config("jamba-v0.1-52b").reduced(
            moe_experts=8, moe_capacity_factor=16.0, dtype="float32")
        cfg_s = dataclasses.replace(cfg_g, moe_impl="shard_map_ep")
        params = init_params(cfg_g, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                    cfg_g.vocab_size)
        with mesh_context(mesh):
            a = jax.jit(lambda p, t: forward(cfg_g, p, t)[0])(params, tokens)
            b = jax.jit(lambda p, t: forward(cfg_s, p, t)[0])(params, tokens)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-4, rtol=2e-4)
            # grads flow through the shard_map dispatch
            g = jax.grad(lambda p: float(0) + jax.numpy.sum(
                forward(cfg_s, p, tokens)[0].astype(jax.numpy.float32)))(params)
            assert all(np.isfinite(np.asarray(l, np.float32)).all()
                       for l in jax.tree.leaves(g))

        for arch, shape, ov in [
            ("jamba-v0.1-52b", ShapeSpec("p", 64, 8, "prefill"),
             {"moe_impl": "shard_map_ep"}),
            ("llama3-405b", ShapeSpec("d", 64, 8, "decode"),
             {"shard_cache_seq": True}),
            ("llama3-405b", ShapeSpec("t", 64, 8, "train"),
             {"seq_shard_activations": True, "remat_policy": "planner"}),
        ]:
            cfg = dataclasses.replace(get_config(arch).reduced(), **ov)
            dr.build_lowered(cfg, shape, mesh).compile()
        print("OK")
        """
    )
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=".", timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_shard_cache_seq_spec():
    import functools

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import init_params
    from repro.sharding.strategy import cache_specs
    from tests.sharding.test_strategy import MESHES

    import dataclasses

    cfg = dataclasses.replace(get_config("llama3-405b"), shard_cache_seq=True)
    cache_shape = jax.eval_shape(
        lambda: __import__("repro.models", fromlist=["make_cache"]).make_cache(
            cfg, 8, 64
        )
    )
    spec = cache_specs(cfg, cache_shape, MESHES["single"])
    k = spec["sub0"]["k"]
    assert tuple(k)[3] == "model"  # sequence dim sharded
    assert tuple(k)[2] is None     # kv heads not sharded (8 < 16)
