"""Flash attention Pallas kernel vs jnp oracle (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention_fwd, ref
from repro.kernels.flash_attention import flash_attention

jax.config.update("jax_enable_x64", False)


def make_qkv(b, hq, hkv, sq, sk, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), dtype)
    return q, k, v


TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5), jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,sq,sk,d,causal",
    [
        (1, 2, 2, 64, 64, 32, True),       # MHA, square
        (2, 4, 2, 32, 32, 16, True),       # GQA group=2
        (1, 4, 1, 48, 48, 32, False),      # MQA, non-causal, pad to block
        (1, 2, 2, 40, 72, 16, False),      # ragged q/k, both padded
        (1, 8, 2, 128, 128, 64, True),     # block-sized
    ],
)
def test_fwd_matches_ref(b, hq, hkv, sq, sk, d, causal, dtype):
    if causal and sq != sk:
        pytest.skip("causal assumes aligned q/k here")
    q, k, v = make_qkv(b, hq, hkv, sq, sk, d, dtype)
    out, lse = flash_attention_fwd(
        q, k, v, causal=causal, block_q=32, block_k=32, interpret=True
    )
    expect = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **TOL[dtype]
    )
    # lse finite on valid rows
    assert np.isfinite(np.asarray(lse)).all()


def test_fwd_lse_matches_ref():
    q, k, v = make_qkv(1, 2, 2, 64, 64, 32, jnp.float32)
    _, lse = flash_attention_fwd(q, k, v, causal=True, block_q=32, block_k=32,
                                 interpret=True)
    _, lse_ref = ref.attention_with_lse(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("hq,hkv", [(2, 2), (4, 2)])
def test_grads_match_ref(causal, hq, hkv):
    q, k, v = make_qkv(1, hq, hkv, 64, 64, 32, jnp.float32, seed=3)

    def loss_kernel(q, k, v):
        o = flash_attention(q, k, v, causal, None, 32, 32, True)
        return jnp.sum(o * jnp.cos(o))

    def loss_ref(q, k, v):
        o = ref.attention(q, k, v, causal=causal)
        return jnp.sum(o * jnp.cos(o))

    g_kernel = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for gk, gr, name in zip(g_kernel, g_ref, "qkv"):
        np.testing.assert_allclose(
            np.asarray(gk), np.asarray(gr), atol=2e-4, rtol=2e-4,
            err_msg=f"d{name} mismatch",
        )


def test_decode_shape_single_query():
    # decode: one query against a long KV (non-causal with offset semantics
    # handled by the caller masking kv_len)
    q, k, v = make_qkv(2, 4, 4, 1, 256, 32, jnp.float32, seed=5)
    out, _ = flash_attention_fwd(q, k, v, causal=False, block_q=8, block_k=64,
                                 interpret=True)
    expect = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5,
                               rtol=2e-5)
