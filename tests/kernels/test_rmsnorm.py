import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref, rmsnorm


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(8, 64), (3, 5, 128), (300, 256)])
@pytest.mark.parametrize("with_residual", [False, True])
def test_rmsnorm_matches_ref(shape, dtype, with_residual):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(k1, shape, dtype)
    w = jax.random.normal(k2, shape[-1:], dtype) * 0.1 + 1.0
    r = jax.random.normal(k3, shape, dtype) if with_residual else None
    got = rmsnorm(x, w, residual=r, block_rows=64, interpret=True)
    expect = ref.rmsnorm(x, w, residual=r)
    tol = dict(atol=1e-5, rtol=1e-5) if dtype == jnp.float32 else dict(atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(expect, np.float32), **tol
    )
