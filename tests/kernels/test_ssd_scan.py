"""SSD chunked-scan Pallas kernel vs sequential-recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref, ssd_scan


def make_inputs(b, s, h, p, n, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32)) * 0.1
    a = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.5)
    bm = jax.random.normal(ks[3], (b, s, n), dtype) / (n**0.5)
    cm = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, n), dtype) / (n**0.5)
    return x, dt.astype(dtype), a, bm, cm


def test_chunked_ref_matches_sequential_ref():
    x, dt, a, bm, cm = make_inputs(2, 128, 2, 16, 8, jnp.float32)
    seq = ref.ssd_scan_sequential(x, dt, a, bm, cm)
    chk = ref.ssd_scan_chunked(x, dt, a, bm, cm, chunk=32)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(chk), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,p,n,chunk",
    [
        (1, 64, 2, 16, 8, 16),
        (2, 128, 1, 32, 16, 32),
        (1, 96, 3, 8, 8, 32),   # chunk not power-of-two count
    ],
)
def test_kernel_matches_sequential(b, s, h, p, n, chunk, dtype):
    x, dt, a, bm, cm = make_inputs(b, s, h, p, n, dtype, seed=7)
    got = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    expect = ref.ssd_scan_sequential(x, dt, a, bm, cm)
    tol = dict(atol=2e-4, rtol=2e-4) if dtype == jnp.float32 else dict(atol=5e-2, rtol=5e-2)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(expect, np.float32), **tol
    )


def test_state_carries_across_chunks():
    """A single impulse at t=0 must influence outputs in later chunks."""
    b, s, h, p, n = 1, 64, 1, 4, 4
    x = jnp.zeros((b, s, h, p)).at[0, 0].set(1.0)
    dt = jnp.full((b, s, h), 0.05)
    a = jnp.array([-0.1])
    bm = jnp.ones((b, s, n))
    cm = jnp.ones((b, s, n))
    y = ssd_scan(x, dt, a, bm, cm, chunk=16, interpret=True)
    assert float(jnp.abs(y[0, -1]).sum()) > 0, "decayed state lost across chunks"
    expect = ref.ssd_scan_sequential(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect), atol=1e-5)
