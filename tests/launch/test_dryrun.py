"""Dry-run machinery: collective parsing unit tests + a subprocess dry-run of
a tiny arch on an 8-device mesh exercising the real dryrun.py code path."""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.launch.dryrun import _bytes_of_type, _pick_unroll, collective_bytes


def test_bytes_of_type():
    assert _bytes_of_type("bf16[8,128]") == 8 * 128 * 2
    assert _bytes_of_type("f32[2,2]") == 16
    assert _bytes_of_type("(bf16[4], f32[4])") == 8 + 16
    assert _bytes_of_type("pred[]") == 1  # scalar: empty dims
    assert _bytes_of_type("token[]") == 0  # non-numeric types ignored


def test_collective_bytes_parsing():
    hlo = textwrap.dedent(
        """
        ENTRY main {
          %p = bf16[16,64]{1,0} parameter(0)
          %ar = bf16[16,64]{1,0} all-reduce(%p), replica_groups={}
          %ag = bf16[32,64]{1,0} all-gather(%p), dimensions={0}
          %rs.1 = f32[8,64]{1,0} reduce-scatter(%p), dimensions={0}
          %cp = bf16[16,64]{1,0} collective-permute-start(%p)
          %add = bf16[16,64]{1,0} add(%p, %p)
        }
        """
    )
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 64 * 2
    assert out["all-gather"] == 32 * 64 * 2
    assert out["reduce-scatter"] == 8 * 64 * 4
    assert out["collective-permute"] == 16 * 64 * 2
    assert out["count"] == 4
    assert out["total"] == sum(
        out[k] for k in
        ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")
    )


def test_pick_unroll():
    assert _pick_unroll(126) == 9
    assert _pick_unroll(28) == 7
    assert _pick_unroll(64) == 8
    assert _pick_unroll(4) == 4
    assert _pick_unroll(1) == 1


@pytest.mark.slow
def test_dryrun_subprocess_tiny_mesh(tmp_path):
    """All three step kinds lower+compile for a reduced arch on (2,4) mesh."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, json
        import repro.launch.dryrun as dr
        from repro.configs import get_config
        from repro.configs.base import ShapeSpec
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("jamba-v0.1-52b").reduced()
        for spec in [ShapeSpec("t", 64, 8, "train"),
                     ShapeSpec("p", 64, 8, "prefill"),
                     ShapeSpec("d", 64, 8, "decode")]:
            low = dr.build_lowered(cfg, spec, mesh)
            comp = low.compile()
            cb = dr.collective_bytes(comp.as_text())
            assert cb["count"] > 0, spec.kind
            ma = comp.memory_analysis()
            assert ma.temp_size_in_bytes >= 0
        print("OK")
        """
    )
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=".", timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
